"""Unseen-estimator accuracy: the statistical core of HPDedup (paper Alg. 1)."""

import numpy as np
import pytest

from repro.core.ffh import occurrence_counts
from repro.core.unseen import (
    ldss_batch,
    ldss_from_counts,
    unseen_estimate_from_counts,
    unseen_estimate_jax_from_counts,
)


def _sample(pop, rate, rng):
    k = max(50, int(rate * pop.size))
    return occurrence_counts(rng.choice(pop, size=k, replace=False))


CASES = {
    "uniform5x": (np.repeat(np.arange(2000), 5), 0.15),
    "mostly-unique": (np.concatenate([np.arange(8000), np.arange(1000), np.arange(1000)]), 0.15),
    "dup10x": (np.repeat(np.arange(1000), 10), 0.15),
    "all-unique": (np.arange(10000), 0.15),
}


@pytest.mark.parametrize("name", list(CASES))
def test_ref_estimator_accuracy(name):
    pop, rate = CASES[name]
    rng = np.random.default_rng(0)
    counts = _sample(pop, rate, rng)
    est = unseen_estimate_from_counts(counts, pop.size)
    true = len(np.unique(pop))
    assert abs(est - true) / true < 0.25, (name, est, true)


@pytest.mark.parametrize("name", list(CASES))
def test_jax_estimator_matches_ref(name):
    pop, rate = CASES[name]
    rng = np.random.default_rng(1)
    counts = _sample(pop, rate, rng)
    ref = unseen_estimate_from_counts(counts, pop.size)
    jx = float(unseen_estimate_jax_from_counts([counts], np.array([pop.size]))[0])
    assert abs(jx - ref) / max(ref, 1.0) < 0.25, (name, jx, ref)


def test_ldss_ordering_drives_cache_priorities():
    """LDSS must rank mail-like >> ftp-like streams (what the cache needs)."""
    rng = np.random.default_rng(2)
    probs = 1.0 / np.arange(1, 501)
    mail = rng.choice(500, size=5000, p=probs / probs.sum())
    ftp = np.concatenate([np.arange(4500), rng.choice(4500, 500)])
    l_mail = ldss_from_counts(_sample(mail, 0.15, rng), mail.size)
    l_ftp = ldss_from_counts(_sample(ftp, 0.15, rng), ftp.size)
    assert l_mail > 5 * max(l_ftp, 1.0)


def test_ldss_batch_matches_single():
    rng = np.random.default_rng(3)
    pops = [np.repeat(np.arange(500), 10), np.arange(5000)]
    counts = [_sample(p, 0.15, rng) for p in pops]
    batch = ldss_batch(counts, np.array([p.size for p in pops]))
    singles = [ldss_from_counts(c, p.size, ref=False) for c, p in zip(counts, pops)]
    np.testing.assert_allclose(batch, singles, rtol=1e-5)


def test_small_and_empty_samples():
    assert unseen_estimate_from_counts(np.array([], dtype=np.int64), 100) == 0.0
    assert unseen_estimate_from_counts(np.array([1, 1]), 2) == 2.0
